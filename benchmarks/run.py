"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick]

Prints one CSV block per benchmark, prefixed with `== <name> ==`, plus a
`name,us_per_call,derived` summary line per benchmark (harness timing).
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer suites/rates")
    args = ap.parse_args()

    from . import (
        bench_estimation,
        bench_grad_compress,
        bench_kernels3d,
        bench_overhead,
        bench_quantizers,
        bench_roofline,
        bench_selection,
        bench_throughput,
    )

    benches = [
        ("estimation_accuracy_T2_T5",
         (lambda: bench_estimation.run(rates=(0.05,), suites=("ATM",))) if args.quick
         else bench_estimation.run),
        ("selection_accuracy_F6_F7",
         (lambda: bench_selection.run(eb_rels=(1e-3,), suites=("ATM",))) if args.quick
         else bench_selection.run),
        ("overhead_T6",
         (lambda: bench_overhead.run(rates=(0.05,), suites=("ATM",))) if args.quick
         else bench_overhead.run),
        ("throughput_F8_F9", bench_throughput.run),
        ("quantizer_families_S514", bench_quantizers.run),
        ("grad_compress_beyond_paper",
         (lambda: bench_grad_compress.run(steps=10)) if args.quick
         else bench_grad_compress.run),
        ("kernels3d_vs_fallback",
         (lambda: bench_kernels3d.run(sizes=(128,), repeat=1)) if args.quick
         else bench_kernels3d.run),
        ("roofline_from_dryrun", bench_roofline.run),
    ]
    summary = []
    for name, fn in benches:
        print(f"== {name} ==", flush=True)
        t0 = time.perf_counter()
        try:
            rows = fn()
            for r in rows:
                print(r)
            derived = len(rows) - 1
        except Exception as e:  # noqa: BLE001
            print(f"ERROR,{type(e).__name__},{e}")
            derived = -1
        dt = (time.perf_counter() - t0) * 1e6
        summary.append(f"{name},{dt:.0f},{derived}")
        print(flush=True)
    print("== summary (name,us_per_call,derived) ==")
    for s in summary:
        print(s)


if __name__ == "__main__":
    main()
