"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/*.json and prints, per (arch x shape x mesh):
compute/memory/collective terms (seconds), dominant term, MODEL_FLOPS,
useful-compute ratio."""

from __future__ import annotations

import glob
import json
import os

from .common import csv_row

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def run(dryrun_dir: str = DEFAULT_DIR, mesh: str | None = "single"):
    rows = [csv_row("arch", "shape", "mesh", "status", "t_compute_s", "t_memory_s",
                    "t_collective_s", "dominant", "model_flops", "useful_ratio",
                    "hbm_args_MB", "compile_s")]
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skip":
            rows.append(csv_row(rec["arch"], rec["shape"], rec["mesh"], rec["reason"],
                                "-", "-", "-", "-", "-", "-", "-", "-"))
            continue
        if rec.get("status") != "ok":
            rows.append(csv_row(rec["arch"], rec["shape"], rec["mesh"], "ERROR",
                                "-", "-", "-", "-", "-", "-", "-", "-"))
            continue
        rl = rec["roofline"]
        rows.append(csv_row(
            rec["arch"], rec["shape"], rec["mesh"], "ok",
            f"{rl['t_compute_s']:.4g}", f"{rl['t_memory_s']:.4g}",
            f"{rl['t_collective_s']:.4g}", rl["dominant"].replace("t_", "").replace("_s", ""),
            f"{rec['model_flops']:.3g}", f"{rl['useful_flops_ratio']:.3f}",
            f"{rec['memory']['argument_bytes'] / 1e6:.0f}",
            rec.get("compile_seconds", "-"),
        ))
    return rows


def main() -> None:
    for r in run(mesh=None):
        print(r)


if __name__ == "__main__":
    main()
