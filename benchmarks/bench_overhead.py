"""Paper Table 6: estimation (selection) time overhead vs SZ/ZFP compression
time, per sampling rate — plus the DESIGN.md §8 repeated-save scenario
(`run_repeated_save`): the same tree saved step after step through a
`DecisionCache`, reporting warm selection overhead as a percentage of
encode time, the cache hit rate, and any warm-vs-cold decision flips."""

from __future__ import annotations


from repro.core import select, sz_compress, zfp_compress
from .common import SUITES, csv_row, timer


def run(rates=(0.01, 0.05, 0.10), eb_rel: float = 1e-3, suites=("ATM", "Hurricane", "NYX")):
    rows = [csv_row("suite", "r_sp", "est_seconds_per_field",
                    "pct_of_sz_time", "pct_of_zfp_time")]
    for suite_name in suites:
        fields = dict(list(SUITES[suite_name]().items())[:6])
        # compression baselines
        t_sz = t_zfp = 0.0
        for f in fields.values():
            eb = eb_rel * float(f.max() - f.min())
            _, dt = timer(sz_compress, f, eb)
            t_sz += dt
            _, dt = timer(zfp_compress, f, eb)
            t_zfp += dt
        t_sz /= len(fields)
        t_zfp /= len(fields)
        for r_sp in rates:
            # warm-up: in the paper's in-situ model the same fields recur
            # every timestep, so the one-time jit compile is amortized away
            f0 = next(iter(fields.values()))
            select(f0, eb_abs=eb_rel * float(f0.max() - f0.min()), r_sp=r_sp)
            t_est = 0.0
            for f in fields.values():
                eb = eb_rel * float(f.max() - f.min())
                _, dt = timer(lambda: select(f, eb_abs=eb, r_sp=r_sp))
                t_est += dt
            t_est /= len(fields)
            rows.append(csv_row(
                suite_name, r_sp, f"{t_est:.4f}",
                f"{100 * t_est / t_sz:.1f}", f"{100 * t_est / t_zfp:.1f}",
            ))
    return rows


def run_repeated_save(
    n_steps: int = 4,
    eb_rel: float = 1e-3,
    n_fields: int = 6,
    atm_size=(384, 768),
    hur_size=(32, 96, 96),
):
    """The warm-save workload (DESIGN.md §8): select+encode the SAME tree
    `n_steps` times through one `DecisionCache`. Step 0 cold-populates;
    later steps should be all hits, with selection overhead a small
    fraction of encode time. Returns (csv rows, summary dict): the
    summary carries `warm_overhead_pct` (warm selection time / encode
    time), `warm_save_speedup` (cold / warm selection time),
    `hit_rate`, and `flips` — fields whose warm decision differs from
    the cold reference (must be empty: validated hits replay cold
    decisions bit-identically)."""
    from repro.core import encode_with_selection, select_many
    from repro.core.decision_cache import DecisionCache
    from repro.core.policy import Policy

    fields = {}
    fields.update(
        {f"atm/{k}": v
         for k, v in list(SUITES["ATM"](size=atm_size).items())[:n_fields]}
    )
    fields.update(
        {f"hur/{k}": v
         for k, v in list(SUITES["Hurricane"](size=hur_size).items())[:n_fields]}
    )
    names, arrs = list(fields), list(fields.values())
    pol = Policy.fixed_accuracy(eb_rel=eb_rel)
    # jit warm-up, then the cold reference (the in-situ model: recurring
    # shapes mean the one-time compiles are amortized away); best-of-3,
    # matching the warm side's best-warm-step, so the gated ratio is not
    # at the mercy of one timer sample
    select_many(arrs, policy=pol)
    cold_runs = [timer(lambda: select_many(arrs, policy=pol)) for _ in range(3)]
    cold_sels, t_cold = min(cold_runs, key=lambda r: r[1])
    cache = DecisionCache()
    rows = [csv_row("step", "select_seconds", "encode_seconds",
                    "overhead_pct", "hits", "misses")]
    flips: set[str] = set()
    warm_times = []
    t_enc = 1e-9
    for step in range(n_steps):
        cache.reset_stats()
        sels, t_sel = timer(
            lambda: select_many(arrs, policy=pol, cache=cache, names=names)
        )
        _, t_enc = timer(
            lambda: [encode_with_selection(x, s) for x, s in zip(arrs, sels)]
        )
        if step > 0:
            warm_times.append(t_sel)
            flips.update(
                n for n, a, b in zip(names, sels, cold_sels) if a != b
            )
        st = cache.stats()
        rows.append(csv_row(
            step, f"{t_sel:.4f}", f"{t_enc:.4f}",
            f"{100.0 * t_sel / t_enc:.2f}", st["hits"], st["misses"],
        ))
    t_warm = min(warm_times)  # steady-state: best warm step
    summary = dict(
        cold_select_seconds=t_cold,
        warm_select_seconds=t_warm,
        encode_seconds=t_enc,
        warm_overhead_pct=100.0 * t_warm / t_enc,
        warm_save_speedup=t_cold / max(t_warm, 1e-9),
        hit_rate=cache.stats()["hit_rate"],
        flips=sorted(flips),
    )
    return rows, summary


def main() -> None:
    for r in run():
        print(r)
    rows, summary = run_repeated_save()
    print()
    for r in rows:
        print(r)
    print(
        f"warm overhead {summary['warm_overhead_pct']:.2f}% of encode, "
        f"{summary['warm_save_speedup']:.1f}x over cold selection, "
        f"hit rate {summary['hit_rate']:.2f}, flips {summary['flips']}"
    )


if __name__ == "__main__":
    main()
