"""Paper Table 6: estimation (selection) time overhead vs SZ/ZFP compression
time, per sampling rate."""

from __future__ import annotations


from repro.core import select, sz_compress, zfp_compress
from .common import SUITES, csv_row, timer


def run(rates=(0.01, 0.05, 0.10), eb_rel: float = 1e-3, suites=("ATM", "Hurricane", "NYX")):
    rows = [csv_row("suite", "r_sp", "est_seconds_per_field",
                    "pct_of_sz_time", "pct_of_zfp_time")]
    for suite_name in suites:
        fields = dict(list(SUITES[suite_name]().items())[:6])
        # compression baselines
        t_sz = t_zfp = 0.0
        for f in fields.values():
            eb = eb_rel * float(f.max() - f.min())
            _, dt = timer(sz_compress, f, eb)
            t_sz += dt
            _, dt = timer(zfp_compress, f, eb)
            t_zfp += dt
        t_sz /= len(fields)
        t_zfp /= len(fields)
        for r_sp in rates:
            # warm-up: in the paper's in-situ model the same fields recur
            # every timestep, so the one-time jit compile is amortized away
            f0 = next(iter(fields.values()))
            select(f0, eb_abs=eb_rel * float(f0.max() - f0.min()), r_sp=r_sp)
            t_est = 0.0
            for f in fields.values():
                eb = eb_rel * float(f.max() - f.min())
                _, dt = timer(lambda: select(f, eb_abs=eb, r_sp=r_sp))
                t_est += dt
            t_est /= len(fields)
            rows.append(csv_row(
                suite_name, r_sp, f"{t_est:.4f}",
                f"{100 * t_est / t_sz:.1f}", f"{100 * t_est / t_zfp:.1f}",
            ))
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
