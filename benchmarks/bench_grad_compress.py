"""Beyond-paper: error-feedback gradient compression — convergence and
wire-traffic reduction on a small LM (the paper's Stage I/II applied to
distributed-training traffic; DESIGN.md §2)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, synthetic_batch
from repro.models import build_model, reduced_for_smoke
from repro.models import nn as rnn
from repro.optim import AdamWConfig, GradCompressConfig
from repro.runtime.steps import init_opt_state, make_train_step
from .common import csv_row


def _train(compress: bool, steps: int = 40):
    cfg = reduced_for_smoke(get_config("smollm-360m")).scaled(n_layers=2)
    model = build_model(cfg)
    params = rnn.init_tree(model.desc(), jax.random.key(0))
    gc = GradCompressConfig(eb_rel=1e-3) if compress else None
    opt = init_opt_state(params, gc)
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, total_steps=steps), gc))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=4)
    losses, wire = [], []
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in synthetic_batch(dcfg, s).items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        if "wire_bits_per_value" in m:
            wire.append(float(m["wire_bits_per_value"]))
    return losses, wire


def run(steps: int = 40):
    base, _ = _train(False, steps)
    comp, wire = _train(True, steps)
    rows = [csv_row("variant", "loss_start", "loss_end", "wire_bits_per_value",
                    "traffic_reduction_x")]
    rows.append(csv_row("fp32_grads", f"{base[0]:.4f}", f"{np.mean(base[-5:]):.4f}", 32.0, 1.0))
    wb = float(np.mean(wire))
    rows.append(csv_row("eb_quantized_ef", f"{comp[0]:.4f}", f"{np.mean(comp[-5:]):.4f}",
                        f"{wb:.2f}", f"{32.0 / wb:.1f}"))
    return rows


def main() -> None:
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
