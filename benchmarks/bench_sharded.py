"""Shard-local compression vs gather-then-compress (DESIGN.md §6).

Two end-to-end checkpoint strategies over the SAME sharded train-state
pytree on an 8-device emulated ('data', 'model') mesh:

* gather-then-compress — the pre-§6 pipeline: `CheckpointManager` with
  `sharded=False` gathers every tensor to host (np.asarray inside
  `_leaf_items`), runs the batched selection engine on the gathered
  copies, and encodes whole fields;
* shard-local — `sharded=True`: decisions from per-shard statistics
  reconciled in-graph (no gather), per-shard segment encoding.

Standalone (needs the device-count flag BEFORE jax initializes, which the
module header sets):

    PYTHONPATH=src python -m benchmarks.bench_sharded [--fields 8] [--dim 1024]

The first sharded save compiles the engine's shard_map program (reported
separately as warmup); steady-state numbers are what an in-situ training
loop pays every checkpoint. Decision/value parity is asserted, not
assumed.
"""

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()

import argparse
import json
import tempfile
import time

import numpy as np


def run(n_fields: int = 8, dim: int = 1024, repeat: int = 3, eb_rel: float = 1e-3):
    import jax

    from repro.checkpoint import CheckpointConfig, CheckpointManager
    from repro.core import Policy
    from repro.launch.mesh import make_emulated_mesh
    from repro.launch.shardckpt import synth_state

    from .common import csv_row

    mesh = make_emulated_mesh((2, 4), ("data", "model"))
    tree, _ = synth_state(mesh, n_fields, dim)
    raw_mb = sum(x.size * np.dtype(str(x.dtype)).itemsize for x in jax.tree_util.tree_leaves(tree)) / 1e6
    rows = [csv_row("strategy", "fields", "dim", "devices", "warmup_s",
                    "save_s_median", "MB", "ratio", "speedup_vs_gather")]
    times = {}
    sizes = {}
    bits = {}
    for strategy, sharded in (("gather_then_compress", False), ("shard_local", True)):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(
                CheckpointConfig(
                    directory=d, policy=Policy.fixed_accuracy(eb_rel=eb_rel),
                    sharded=sharded, keep_n=1,
                )
            )
            t0 = time.perf_counter()
            mgr.save(0, tree)  # compiles (shard_map program / jit cache)
            warm = time.perf_counter() - t0
            ts = []
            for it in range(repeat):
                t0 = time.perf_counter()
                path = mgr.save(1 + it, tree)
                ts.append(time.perf_counter() - t0)
            with open(os.path.join(path, "manifest.json")) as f:
                man = json.load(f)
            _, restored = mgr.restore()
            # min, not median: the ratio below divides two of these, and
            # scheduler noise on small hosts only ever ADDS time — the
            # fastest repeat is the least-contended estimate of each side
            times[strategy] = (warm, float(np.min(ts)))
            sizes[strategy] = man["total_bytes"]
            bits[strategy] = man["selection_bits"]
            vals = restored
        if strategy == "gather_then_compress":
            ref_vals = vals
        else:
            flips = [k for k in bits["gather_then_compress"]
                     if bits["gather_then_compress"][k] != bits["shard_local"].get(k)]
            mism = [k for k in ref_vals if not np.array_equal(ref_vals[k], vals[k])]
            assert not flips, f"decision flips vs unsharded: {flips[:4]}"
            assert not mism, f"restored-value mismatches vs unsharded: {mism[:4]}"
    base = times["gather_then_compress"][1]
    for strategy in ("gather_then_compress", "shard_local"):
        warm, med = times[strategy]
        rows.append(csv_row(
            strategy, n_fields, dim, 8, f"{warm:.2f}", f"{med:.2f}",
            f"{sizes[strategy] / 1e6:.2f}",
            f"{raw_mb * 1e6 / max(sizes[strategy], 1):.2f}",
            f"{base / med:.2f}",
        ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fields", type=int, default=8)
    ap.add_argument("--dim", type=int, default=1024)
    ap.add_argument("--repeat", type=int, default=3)
    args = ap.parse_args()
    for row in run(args.fields, args.dim, args.repeat):
        print(row)


if __name__ == "__main__":
    main()
