"""Render the §Roofline table into EXPERIMENTS.md from results/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.render_experiments
"""

from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")


def roofline_markdown(dryrun_dir: str) -> str:
    rows = [
        "| arch | shape | mesh | t_compute (s) | t_memory (s) | t_collective (s) "
        "| dominant | MODEL_FLOPS | useful ratio | fix-it note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    notes = {
        ("t_memory_s", "decode"): "KV/state residency: shard seq dim (seqkv variant) or quantize cache",
        ("t_memory_s", "train"): "activation traffic: larger fusion blocks, bf16 masters, fewer remat reads",
        ("t_memory_s", "prefill"): "attention working set: longer q-chunks, KV in bf16",
        ("t_collective_s", "train"): "FSDP weight gathers + grad all-reduce: tp_weights rules / grad compression",
        ("t_collective_s", "prefill"): "activation resharding between TP ops: fuse constraints",
        ("t_compute_s", "train"): "already compute-bound: raise MXU occupancy (tile alignment)",
    }
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        if "__tp_weights" in path or "__seqkv" in path:
            continue
        rec = json.load(open(path))
        a, s, m = rec["arch"], rec["shape"], rec["mesh"]
        if rec.get("status") == "skip":
            rows.append(f"| {a} | {s} | {m} | — | — | — | {rec['reason']} | — | — | sub-quadratic attn required |")
            continue
        if rec.get("status") != "ok":
            rows.append(f"| {a} | {s} | {m} | ERR | ERR | ERR | {rec.get('error','?')[:40]} | — | — | — |")
            continue
        rl = rec["roofline"]
        dom = rl["dominant"]
        kind = "train" if s.startswith("train") else ("prefill" if s.startswith("prefill") else "decode")
        note = notes.get((dom, kind), "")
        rows.append(
            f"| {a} | {s} | {m} | {rl['t_compute_s']:.3g} | {rl['t_memory_s']:.3g} "
            f"| {rl['t_collective_s']:.3g} | **{dom.replace('t_','').replace('_s','')}** "
            f"| {rec['model_flops']:.2e} | {rl['useful_flops_ratio']:.3f} | {note} |"
        )
    return "\n".join(rows)


def main() -> None:
    dryrun_dir = os.path.join(ROOT, "results", "dryrun")
    table = roofline_markdown(dryrun_dir)
    exp = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(exp).read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in text:
        text = text.replace(marker, table, 1)
    else:
        # refresh: replace between the section headers
        import re

        text = re.sub(
            r"(## §Roofline\n(?:.*?\n)*?)\|.*?(\n\n## §Perf)",
            lambda m: m.group(1) + table + m.group(2),
            text,
            flags=re.S,
        )
    open(exp, "w").write(text)
    print(f"rendered {table.count(chr(10)) + 1} rows into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
